"""Bucket-store subsystem: CSR invariants, kernel sweeps, engine parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import range_lsh, simple_lsh, topk
from repro.core.bucket_index import (build_bucket_index, bucket_sizes,
                                     rank_table)
from repro.core.engine import AUTO_DENSE_RATIO, QueryEngine, select_engine
from repro.core.probe import probe_table
from repro.kernels import ops, ref


@pytest.fixture(scope="module")
def range_index(longtail_ds):
    return range_lsh.build(longtail_ds.items, jax.random.PRNGKey(1), 16, 8)


@pytest.fixture(scope="module")
def simple_index(longtail_ds):
    return simple_lsh.build(longtail_ds.items, jax.random.PRNGKey(1), 16)


def test_csr_invariants(range_index):
    b = build_bucket_index(range_index)
    n = range_index.items.shape[0]
    ids = np.asarray(b.item_ids)
    start = np.asarray(b.bucket_start)
    # item_ids is a permutation of [0, N)
    assert sorted(ids.tolist()) == list(range(n))
    # offsets partition [0, N) into non-empty runs
    assert start[0] == 0 and start[-1] == n
    assert np.all(np.diff(start) >= 1)
    # every item in bucket k has the bucket's (range_id, code)
    codes = np.asarray(range_index.codes)
    rid = np.asarray(range_index.range_id)
    bc = np.asarray(b.bucket_code)
    br = np.asarray(b.bucket_rid)
    for k in (0, len(br) // 2, len(br) - 1):
        members = ids[start[k]:start[k + 1]]
        assert np.all(rid[members] == br[k])
        assert np.all(codes[members] == bc[k])
        # within a bucket, CSR keeps ascending item id (the tie-break)
        assert np.all(np.diff(members) > 0)
    # directory rows are unique keys in (rid, code) order
    full = np.concatenate([br[:, None].astype(np.int64),
                           bc.astype(np.int64)], axis=1)
    assert np.all((full[1:] > full[:-1]).any(axis=1))
    first_diff = np.argmax(full[1:] != full[:-1], axis=1)
    cmp = full[np.arange(len(full) - 1), first_diff] < \
        full[1 + np.arange(len(full) - 1), first_diff]
    assert np.all(cmp)
    # sizes sum to N
    assert int(bucket_sizes(b).sum()) == n


def test_rank_table_inverts_probe_table(range_index):
    L = range_index.hash_bits
    tab = probe_table(range_index.upper, L, range_index.eps)
    rank = np.asarray(rank_table(range_index.upper, L, range_index.eps))
    j = np.asarray(tab.range_idx)
    l = np.asarray(tab.match_cnt)
    # entry probed i-th has rank i
    np.testing.assert_array_equal(rank[j, l], np.arange(len(j)))


BUCKET_MATCH_SHAPES = [(8, 64, 1), (37, 771, 2), (64, 512, 4), (1, 100, 3)]


@pytest.mark.parametrize("q,b,w", BUCKET_MATCH_SHAPES)
def test_bucket_match_matches_ref(q, b, w):
    k1, k2 = jax.random.PRNGKey(q), jax.random.PRNGKey(b)
    qc = jax.random.bits(k1, (q, w), jnp.uint32)
    bc = jax.random.bits(k2, (b, w), jnp.uint32)
    got = ops.bucket_match(qc, bc, 32 * w, impl="pallas")
    want = ref.bucket_match_ref(qc, bc, 32 * w)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("q,s,p", [(4, 16, 40), (7, 65, 64), (1, 3, 5),
                                   (16, 128, 100)])
def test_bucket_gather_matches_ref(q, s, p):
    rng = np.random.default_rng(q * 31 + s)
    sizes = rng.integers(1, 7, (q, s)).astype(np.int32)
    # ensure every query's runs cover the probe budget (the contract)
    sizes[:, -1] += np.maximum(0, p - sizes.sum(axis=1)).astype(np.int32)
    starts = rng.integers(0, 10_000, (q, s)).astype(np.int32)
    cum = np.concatenate([np.zeros((q, 1), np.int32),
                          np.cumsum(sizes, axis=1, dtype=np.int32)], axis=1)
    got = ops.bucket_gather(jnp.asarray(cum), jnp.asarray(starts), p,
                            impl="pallas")
    want = ref.bucket_gather_ref(jnp.asarray(cum), jnp.asarray(starts), p)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # first run starts at starts[:, 0]
    np.testing.assert_array_equal(np.asarray(got)[:, 0], starts[:, 0])


@pytest.mark.parametrize("impl", ["ref", "pallas"])
@pytest.mark.parametrize("kind", ["range", "simple"])
def test_engine_parity_dense_vs_bucket(longtail_ds, range_index,
                                       simple_index, kind, impl):
    """Acceptance: for fixed (index, queries, num_probe) the bucket engine
    emits exactly the dense engine's first num_probe items in eq.-12 order,
    stable tie-break included."""
    index = range_index if kind == "range" else simple_index
    buckets = build_bucket_index(index)
    dense = QueryEngine(index, engine="dense", buckets=buckets, impl=impl)
    bucket = QueryEngine(index, engine="bucket", buckets=buckets, impl=impl)
    for num_probe in (32, 333, 1000):
        cd = np.asarray(dense.candidates(longtail_ds.queries, num_probe))
        cb = np.asarray(bucket.candidates(longtail_ds.queries, num_probe))
        np.testing.assert_array_equal(cd, cb)


def test_engine_query_recall_matches_dense_path(longtail_ds, range_index):
    """End-to-end bucket query matches the legacy dense path's recall
    (identical candidate quality; only exact-tie ordering may differ)."""
    items, queries = longtail_ds.items, longtail_ds.queries
    _, truth = topk.exact_mips(queries, items, 10)
    v_legacy, i_legacy = range_lsh.query(range_index, queries, 10, 400)
    buckets = build_bucket_index(range_index)
    v_bucket, i_bucket = range_lsh.query(range_index, queries, 10, 400,
                                         engine="bucket", buckets=buckets)
    r_legacy = float(topk.recall_at(i_legacy, truth))
    r_bucket = float(topk.recall_at(i_bucket, truth))
    assert abs(r_legacy - r_bucket) < 0.05
    assert v_bucket.shape == v_legacy.shape


def test_full_probe_budget_is_exact(longtail_ds, range_index):
    """num_probe == N covers every bucket: bucket-engine query == exact."""
    items, queries = longtail_ds.items, longtail_ds.queries[:8]
    n = items.shape[0]
    ev, ei = topk.exact_mips(queries, items, 5)
    buckets = build_bucket_index(range_index)
    bv, bi = range_lsh.query(range_index, queries, 5, n,
                             engine="bucket", buckets=buckets)
    np.testing.assert_allclose(np.asarray(bv), np.asarray(ev), atol=1e-4)


def test_auto_engine_break_even_heuristic(longtail_ds):
    """engine="auto" resolves by directory size vs N: the BENCH_0001 arms
    (L=16: B/N~0.33 -> bucket 3x; L=32: B/N~0.99 -> dense) land on opposite
    sides of the encoded break-even, and real indexes resolve accordingly."""
    # the measured BENCH_0001 arms
    assert select_engine(33362, 100_000) == "bucket"
    assert select_engine(98662, 100_000) == "dense"
    assert select_engine(0, 1) == "bucket"
    # short codes collapse items into few buckets -> auto picks bucket
    short = range_lsh.build(longtail_ds.items, jax.random.PRNGKey(3), 6, 4)
    eng_short = QueryEngine(short, engine="auto")
    n = longtail_ds.items.shape[0]
    assert eng_short.buckets.num_buckets < AUTO_DENSE_RATIO * n
    assert eng_short.engine == "bucket"
    # long codes make nearly every bucket a singleton -> auto picks dense
    long = range_lsh.build(longtail_ds.items, jax.random.PRNGKey(3), 32, 4)
    eng_long = QueryEngine(long, engine="auto")
    assert eng_long.buckets.num_buckets >= AUTO_DENSE_RATIO * n
    assert eng_long.engine == "dense"


def test_lm_head_bucket_arm_full_budget_matches_exact():
    from repro.models import lm_head

    d, V = 24, 512
    key = jax.random.PRNGKey(0)
    unembed = jax.random.normal(key, (d, V)) * \
        jnp.exp(jax.random.normal(jax.random.PRNGKey(1), (1, V)))
    index = lm_head.build_vocab_index(unembed, jax.random.PRNGKey(2),
                                      code_len=32, num_ranges=8)
    buckets = build_bucket_index(index)
    hidden = jax.random.normal(jax.random.PRNGKey(3), (4, d))
    ev, ei = lm_head.exact_topk_tokens(hidden, unembed, 5)
    bv, bi = lm_head.lsh_topk_tokens(index, hidden, unembed, k=5,
                                     num_probe=V, buckets=buckets)
    np.testing.assert_allclose(np.asarray(bv), np.asarray(ev), atol=1e-4)
    np.testing.assert_array_equal(np.asarray(bi), np.asarray(ei))
