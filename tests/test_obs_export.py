"""Performance-observatory layer (DESIGN.md §14): Chrome trace export,
device-cost attribution on the hot-path spans, and the SLO monitor."""

import itertools
import json

import jax
import jax.numpy as jnp
import pytest

from repro.core.engine import QueryEngine
from repro.core.index import IndexSpec, build
from repro.obs import (RequestClass, RingBufferSink, SloMonitor, Tracker,
                       chrome_trace_events, export_chrome_trace,
                       validate_chrome_trace)
from repro.obs.cost import (BUCKET_STAGES, hash_encode_cost,
                            query_stage_costs, xla_cost)

KEY = jax.random.PRNGKey(5)


def _fake_clock_tracker():
    """Tracker on a deterministic integer clock (1s per reading)."""
    clk = itertools.count()
    ring = RingBufferSink(capacity=4096)
    return Tracker([ring], clock=lambda: float(next(clk))), ring


# -- chrome trace export ------------------------------------------------------


def test_nested_spans_export_balanced_and_carry_attrs(tmp_path):
    t, ring = _fake_clock_tracker()
    with t.span("query"):
        with t.span("hash_encode", attrs={"flops": 8.0, "hbm_bytes": 64.0}):
            pass
        with t.span("gather"):
            pass
    path = str(tmp_path / "trace.json")
    trace = export_chrome_trace(t, path)
    stats = validate_chrome_trace(trace)
    assert stats["span_pairs"] == 3
    assert stats["num_pids"] == 1
    begins = {e["name"]: e for e in trace["traceEvents"]
              if e.get("ph") == "B"}
    assert begins["hash_encode"]["args"]["flops"] == 8.0
    assert begins["hash_encode"]["args"]["path"] == "query/hash_encode"
    assert begins["gather"]["args"]["path"] == "query/gather"
    # children begin after the parent and close before it
    evs = [(e["ph"], e["name"]) for e in trace["traceEvents"]
           if e.get("ph") in "BE"]
    assert evs[0] == ("B", "query") and evs[-1] == ("E", "query")
    # file round-trip
    assert validate_chrome_trace(json.load(open(path))) == stats


def test_multi_shard_export_stable_pids():
    """Fleet view: sorted labels -> stable pids, one process_name
    metadata event each, per-shard streams independently balanced."""
    t0, _ = _fake_clock_tracker()
    t1, _ = _fake_clock_tracker()
    with t0.span("s"):
        pass
    with t1.span("s"):
        with t1.span("inner"):
            pass
    trace = export_chrome_trace({"shard1": t1, "shard0": t0})
    stats = validate_chrome_trace(trace)
    assert stats["num_pids"] == 2
    meta = {e["pid"]: e["args"]["name"] for e in trace["traceEvents"]
            if e.get("ph") == "M"}
    assert meta == {0: "shard0", 1: "shard1"}    # sorted-label order
    by_pid = {}
    for e in trace["traceEvents"]:
        if e.get("ph") == "B":
            by_pid.setdefault(e["pid"], []).append(e["name"])
    assert by_pid[0] == ["s"] and by_pid[1] == ["s", "inner"]


def test_export_without_ring_sink_raises():
    with pytest.raises(ValueError, match="RingBufferSink"):
        export_chrome_trace(Tracker())


def test_zero_duration_sibling_ties_stay_balanced():
    """Timestamp ties (zero-duration spans, sibling end == next begin)
    must not desync the B/E stack — the exporter replays intervals
    through an explicit stack instead of sorting events blind."""
    records = [
        {"type": "span", "name": "a", "path": "a", "depth": 0,
         "t0": 0.0, "dur_s": 1.0},
        {"type": "span", "name": "z", "path": "a/z", "depth": 1,
         "t0": 0.5, "dur_s": 0.0},                 # zero-duration child
        {"type": "span", "name": "b", "path": "b", "depth": 0,
         "t0": 1.0, "dur_s": 1.0},                 # begins at a's end
    ]
    events = chrome_trace_events(records)
    validate_chrome_trace({"traceEvents": events})


def test_validate_rejects_malformed_traces():
    common = {"pid": 0, "tid": 0, "cat": "x"}
    ok_b = {**common, "ph": "B", "name": "s", "ts": 0.0,
            "args": {"path": "s"}}
    with pytest.raises(ValueError, match="dangling"):
        validate_chrome_trace({"traceEvents": [ok_b]})
    with pytest.raises(ValueError, match="without matching B"):
        validate_chrome_trace({"traceEvents": [
            {**common, "ph": "E", "name": "s", "ts": 0.0}]})
    with pytest.raises(ValueError, match="unbalanced"):
        validate_chrome_trace({"traceEvents": [
            ok_b, {**common, "ph": "E", "name": "other", "ts": 1.0}]})
    with pytest.raises(ValueError, match="monotonic"):
        validate_chrome_trace({"traceEvents": [
            {**ok_b, "ts": 5.0},
            {**common, "ph": "E", "name": "s", "ts": 1.0}]})
    with pytest.raises(ValueError, match="args.path"):
        validate_chrome_trace({"traceEvents": [
            {**common, "ph": "B", "name": "s", "ts": 0.0}]})
    with pytest.raises(ValueError, match="traceEvents"):
        validate_chrome_trace({})


# -- device-cost attribution --------------------------------------------------


def test_query_stage_costs_cover_all_stages():
    shape = {"q": 32, "n": 30_000, "d": 32, "code_len": 16,
             "num_buckets": 27_800, "probe_width": 917.0, "k": 10}
    costs = query_stage_costs(shape)
    assert set(costs) == set(BUCKET_STAGES)
    for name, c in costs.items():
        assert c["flops"] > 0 and c["hbm_bytes"] > 0, name
    # re_rank dominates hash_encode at this probe width (sanity ordering)
    assert costs["repro.engine.re_rank"]["flops"] > \
        costs["repro.engine.hash_encode"]["flops"]


def test_engine_spans_carry_predicted_cost_attrs(longtail_ds):
    """Acceptance: the exported trace's hash_encode / segmented_gather /
    re_rank slices carry flops + hbm_bytes args on the bucket path."""
    spec = IndexSpec(family="simple", code_len=16, m=8)
    cidx = build(spec, longtail_ds.items[:800], KEY)
    ring = RingBufferSink()
    t = Tracker([ring])
    eng = QueryEngine(cidx, engine="bucket", tracker=t)
    eng.query(longtail_ds.queries[:4], 5, 100)
    trace = export_chrome_trace(t)
    validate_chrome_trace(trace)
    begins = {e["name"]: e for e in trace["traceEvents"]
              if e.get("ph") == "B"}
    for stage in ("repro.engine.hash_encode",
                  "repro.engine.directory_match",
                  "repro.engine.segmented_gather",
                  "repro.engine.re_rank", "repro.engine.top_k"):
        args = begins[stage]["args"]
        assert args["flops"] > 0 and args["hbm_bytes"] > 0, stage
    # gather cost scales with the probe budget
    assert begins["repro.engine.segmented_gather"]["args"]["flops"] == \
        pytest.approx(4 * 100)


def test_dense_engine_spans_carry_cost_attrs(longtail_ds):
    spec = IndexSpec(family="simple", code_len=16, m=8)
    cidx = build(spec, longtail_ds.items[:800], KEY)
    t = Tracker([RingBufferSink()])
    eng = QueryEngine(cidx, engine="dense", tracker=t)
    eng.query(longtail_ds.queries[:4], 5, 100)
    recs = {r["name"]: r for r in t.sinks[0].query(type="span")}
    for stage in ("repro.engine.dense_match", "repro.engine.dense_select"):
        assert recs[stage]["attrs"]["flops"] > 0, stage


def test_kernel_dispatch_charges_cost_counters():
    from repro.kernels import ops

    t = Tracker()
    ops.set_dispatch_tracker(t)
    try:
        q, d, L = 4, 8, 32
        codes = ops.hash_encode(jnp.ones((q, d)), jnp.ones((d, L)))
        ops.hamming_scan(codes, codes)
    finally:
        ops.set_dispatch_tracker(None)
    pred = hash_encode_cost(q, d, L)
    assert t.counters["repro.kernels.cost.hash_encode.flops"] == \
        pred["flops"]
    assert t.counters["repro.kernels.cost.hash_encode.hbm_bytes"] == \
        pred["hbm_bytes"]
    assert t.counters["repro.kernels.cost.hamming_scan.flops"] == \
        q * q * 1                     # W = 1 packed word at L=32


def test_xla_cost_cross_checks_analytic_hash_encode():
    """The analytic encode model must sit within a small factor of XLA's
    own compiled cost estimate (the MAC count dominates both)."""
    q, d, L = 16, 32, 64
    got = xla_cost(lambda x, A: jnp.sign(x @ A),
                   jnp.ones((q, d)), jnp.ones((d, L)))
    if got is None:
        pytest.skip("backend reports no cost_analysis")
    pred = hash_encode_cost(q, d, L)["flops"]
    assert 0.2 * pred <= got["flops"] <= 5.0 * pred


# -- SLO monitor --------------------------------------------------------------


def test_request_class_validation():
    with pytest.raises(ValueError, match="slo_p50_s"):
        RequestClass(name="a", recall_target=0.9, k=10,
                     slo_p50_s=0.1, slo_p99_s=0.05)
    with pytest.raises(ValueError, match="weight"):
        RequestClass(name="a", recall_target=0.9, k=10,
                     slo_p50_s=0.01, slo_p99_s=0.05, weight=0.0)


def test_slo_monitor_burn_rate_and_breach():
    t = Tracker()
    cls = RequestClass(name="standard", recall_target=0.95, k=10,
                       slo_p50_s=0.01, slo_p99_s=0.05)
    mon = SloMonitor(t, [cls], tolerance=0.0, budget_quantile=0.99,
                     min_samples=10)
    for _ in range(98):
        mon.record("standard", 0.005)
    mon.record("standard", 0.2)
    mon.record("standard", 0.2)           # 2/100 over the p99 bound
    # burn: (2/100) / (1 - 0.99) = 2x the error budget
    assert mon.burn_rate("standard") == pytest.approx(2.0)
    v = mon.evaluate()["standard"]
    assert v["n"] == 100 and v["over_budget"] == 2
    assert v["evaluated"] is True
    assert v["p50_s"] == pytest.approx(0.005, rel=0.05)
    assert v["breached"] is True          # p99 ~0.2 >> 0.05 target
    assert t.counters["repro.slo.breach"] == 1
    ev, = [e for e in t.events if e["name"] == "repro.slo.breach"]
    assert ev["request_class"] == "standard"
    assert ev["burn_rate"] == pytest.approx(2.0)
    assert t.gauges["repro.slo.burn_rate.standard"] == pytest.approx(2.0)
    # latency series lives in a mergeable tracker histogram
    assert t.hists["repro.slo.latency.standard"].count == 100


def test_slo_monitor_within_slo_never_breaches():
    t = Tracker()
    cls = RequestClass(name="a", recall_target=0.9, k=10,
                       slo_p50_s=0.01, slo_p99_s=0.05)
    mon = SloMonitor(t, [cls], min_samples=5)
    for _ in range(50):
        mon.record("a", 0.004)
    v = mon.evaluate()["a"]
    assert v["breached"] is False and v["burn_rate"] == 0.0
    assert "repro.slo.breach" not in t.counters


def test_slo_monitor_min_samples_gate():
    """Few samples: reported but never breach-counted (quantiles of a
    handful of requests are noise, the gate must not flap)."""
    t = Tracker()
    cls = RequestClass(name="a", recall_target=0.9, k=10,
                       slo_p50_s=0.001, slo_p99_s=0.002)
    mon = SloMonitor(t, [cls], min_samples=20)
    for _ in range(5):
        mon.record("a", 1.0)              # wildly over SLO
    v = mon.evaluate()["a"]
    assert v["evaluated"] is False and v["breached"] is False
    assert mon.burn_rate("a") > 1.0       # budget accounting still live


def test_slo_monitor_validation():
    t = Tracker()
    c = RequestClass(name="a", recall_target=0.9, k=10,
                     slo_p50_s=0.01, slo_p99_s=0.05)
    with pytest.raises(ValueError, match="duplicate"):
        SloMonitor(t, [c, c])
    with pytest.raises(ValueError, match="budget_quantile"):
        SloMonitor(t, [c], budget_quantile=1.0)
    mon = SloMonitor(t, [c])
    with pytest.raises(KeyError, match="unknown request class"):
        mon.record("nope", 0.01)
