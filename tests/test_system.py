"""End-to-end behaviour tests for the paper's system.

The paper's full pipeline: ALS matrix factorization produces user/item
embeddings (its Netflix/Yahoo!Music setup) -> RANGE-LSH index over items
-> batched top-k MIPS with the eq.-12 probe order -> exact re-rank. Plus
the headline claim (RANGE-LSH probes fewer items than SIMPLE-LSH at equal
recall) on a long-tail profile.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import range_lsh, simple_lsh, topk
from repro.data.als import als_factorize, synthetic_ratings


def test_als_to_rangelsh_pipeline():
    ratings, weights = synthetic_ratings(jax.random.PRNGKey(0), 150, 800,
                                         density=0.15)
    st = als_factorize(ratings, weights, rank=16, key=jax.random.PRNGKey(1),
                       iters=6)
    assert float(st.loss) < 0.5          # factorization fits
    items, queries = st.items, st.users[:32]
    norms = jnp.linalg.norm(items, axis=1)
    assert float(jnp.max(norms) / jnp.median(norms)) > 1.5  # norm spread

    idx = range_lsh.build(items, jax.random.PRNGKey(2), 32, 16)
    _, truth = topk.exact_mips(queries, items, 10)
    vals, ids = range_lsh.query(idx, queries, 10, 200)
    rec = float(topk.recall_at(ids, truth))
    assert rec > 0.5                     # 25% probed => decent recall
    # returned values are true inner products of returned ids
    got = jnp.einsum("qd,qkd->qk", queries, items[ids])
    np.testing.assert_allclose(np.asarray(vals), np.asarray(got),
                               rtol=1e-4)


def test_paper_headline_fewer_probes_at_equal_recall(longtail_ds):
    """Fig 2 (long-tail row): RANGE-LSH needs fewer probes than SIMPLE-LSH
    to reach the same recall."""
    items, queries = longtail_ds.items, longtail_ds.queries
    n = items.shape[0]
    _, truth = topk.exact_mips(queries, items, 10)
    si = simple_lsh.build(items, jax.random.PRNGKey(1), 32)
    ri = range_lsh.build(items, jax.random.PRNGKey(1), 32, 32)
    grid = [int(n * f) for f in (0.01, 0.02, 0.05, 0.1, 0.2, 0.4, 0.8)]
    rec_s = np.asarray(topk.probed_recall_curve(
        simple_lsh.probe_order(si, queries), truth, grid))
    rec_r = np.asarray(topk.probed_recall_curve(
        range_lsh.probe_order(ri, queries), truth, grid))
    target = 0.5
    probes_s = grid[int(np.argmax(rec_s >= target))] if (rec_s >= target
                                                         ).any() else n
    probes_r = grid[int(np.argmax(rec_r >= target))] if (rec_r >= target
                                                         ).any() else n
    assert probes_r < probes_s


def test_query_engine_returns_sorted_topk(longtail_ds):
    idx = range_lsh.build(longtail_ds.items, jax.random.PRNGKey(0), 32, 16)
    vals, ids = range_lsh.query(idx, longtail_ds.queries[:4], 10, 500)
    v = np.asarray(vals)
    assert np.all(np.diff(v, axis=1) <= 1e-6)   # descending
    assert ids.shape == (4, 10)
