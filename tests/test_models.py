"""Model-layer tests: attention variants, recurrent equivalences, and the
per-arch reduced-config smoke tests (assignment requirement)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (ARCH_IDS, ModelConfig, SSMConfig,
                                XLSTMConfig, get_config)
from repro.data.tokens import SyntheticCorpus
from repro.models import lm
from repro.models.attention import flash_attention, naive_attention


@pytest.mark.parametrize("kwargs", [
    dict(causal=True), dict(causal=True, window=16),
    dict(causal=True, logit_cap=50.0), dict(causal=False),
])
def test_flash_matches_naive(kwargs):
    key = jax.random.PRNGKey(0)
    B, S, H, KV, hd = 2, 64, 8, 4, 16
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, hd))
    pos = jnp.arange(S)
    o1 = flash_attention(q, k, v, pos, pos, q_chunk=16, kv_chunk=16,
                         **kwargs)
    o2 = naive_attention(q, k, v, pos, pos, **kwargs)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


def test_flash_odd_lengths():
    """Non-power-of-two sequence lengths (1500 frames, 4352 vlm seq)."""
    key = jax.random.PRNGKey(0)
    B, Sq, Sk, H, hd = 1, 30, 75, 2, 8
    q = jax.random.normal(key, (B, Sq, H, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, Sk, H, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, Sk, H, hd))
    qp, kp = jnp.arange(Sq), jnp.arange(Sk)
    o1 = flash_attention(q, k, v, qp, kp, causal=False, q_chunk=16,
                         kv_chunk=32)
    o2 = naive_attention(q, k, v, qp, kp, causal=False)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


def _smoke_batch(cfg, B, S):
    batch = dict(SyntheticCorpus(cfg.vocab, S).sample(0, 0, B)._asdict())
    if cfg.num_patches:
        batch["patches"] = jnp.zeros((B, cfg.num_patches, cfg.d_model),
                                     jnp.float32)
    if cfg.is_encoder_decoder:
        batch["frames"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(9), (B, cfg.encoder_frames, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_decode(arch):
    """REDUCED config of each assigned architecture: one train-loss eval
    + one decode step on CPU; asserts shapes and finiteness."""
    cfg = get_config(arch).reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    batch = _smoke_batch(cfg, B, S)
    loss, metrics = lm.train_loss(params, batch, cfg)
    assert jnp.isfinite(loss), arch
    assert float(loss) < 2.0 * jnp.log(cfg.vocab)

    if cfg.is_encoder_decoder:
        from repro.models import encdec
        caches = encdec.init_cache(cfg, B, 32)
        enc = encdec.encoder_forward(params["encoder"], batch["frames"],
                                     cfg)
        ck, cv = encdec.cross_kv(params["layers"], enc, cfg)
        caches["cross_k"], caches["cross_v"] = ck, cv
    else:
        caches = lm.init_cache(cfg, B, 32)
    logits, caches2 = lm.decode_step(
        params, jnp.zeros((B,), jnp.int32), caches,
        jnp.asarray(0, jnp.int32), cfg)
    assert logits.shape == (B, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits[:, :cfg.vocab]).all())


@pytest.mark.parametrize("arch", ["qwen2_1_5b", "minicpm3_4b",
                                  "gemma2_27b", "whisper_small"])
def test_prefill_decode_consistency(arch):
    """Greedy continuation from prefill == decode over the same prefix:
    the (t+1)-th decode logits must match a full forward at position t."""
    cfg = get_config(arch).reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0,
                              cfg.vocab)
    if cfg.is_encoder_decoder:
        from repro.models import encdec
        frames = 0.1 * jax.random.normal(jax.random.PRNGKey(2),
                                         (B, cfg.encoder_frames,
                                          cfg.d_model))
        enc = encdec.encoder_forward(params["encoder"], frames, cfg)
        h_full, _ = encdec.decoder_forward(params, toks, enc, cfg)
        # decode step-by-step
        caches = encdec.init_cache(cfg, B, S + 4)
        ck, cv = encdec.cross_kv(params["layers"], enc, cfg)
        caches["cross_k"], caches["cross_v"] = ck, cv
        hs = []
        for t in range(S + 1):
            h, caches = encdec.decode_step(params, toks[:, t], caches,
                                           jnp.asarray(t, jnp.int32), cfg,
                                           logits_mode="none")
            hs.append(h)
    else:
        h_full, _, _ = lm.backbone_forward(
            params, lm._embed(params, toks, cfg), jnp.arange(S + 1), cfg)
        h_full = lm.rms_norm(h_full, params["final_norm"], cfg.norm_eps)
        caches = lm.init_cache(cfg, B, S + 4)
        hs = []
        for t in range(S + 1):
            h, caches = lm.decode_step(params, toks[:, t], caches,
                                       jnp.asarray(t, jnp.int32), cfg,
                                       logits_mode="none")
            hs.append(h)
    h_dec = jnp.stack(hs, axis=1)
    np.testing.assert_allclose(np.asarray(h_dec, np.float32),
                               np.asarray(h_full, np.float32),
                               atol=5e-2, rtol=5e-2)


def test_moe_routes_and_balances():
    from repro.models.moe import group_capacity, moe_forward, moe_init
    cfg = get_config("granite_moe_1b_a400m").reduced()
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)
    out, aux = moe_forward(p, x, cfg)
    assert out.shape == x.shape
    assert jnp.isfinite(aux)
    assert float(aux) >= 1.0 - 1e-3      # E * sum f_e p_e >= 1 always
    assert group_capacity(16, 4, 2, 1.25) == 10


def test_chunked_loss_matches_dense():
    cfg = get_config("qwen3_0_6b").reduced()
    B, S, D = 2, 16, cfg.d_model
    key = jax.random.PRNGKey(0)
    h = jax.random.normal(key, (B, S, D), jnp.float32)
    unembed = jax.random.normal(jax.random.PRNGKey(1),
                                (D, cfg.padded_vocab), jnp.float32) * 0.05
    labels = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
    mask = jnp.ones((B, S), jnp.float32)
    got = lm.chunked_loss(h, unembed, labels, mask, cfg, chunk=4)
    logits = h @ unembed
    logits = lm.mask_padding_logits(logits, cfg)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    want = jnp.mean(logz - gold)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)
