"""Streaming index subsystem: delta-scan kernel, merge parity with
from-scratch rebuilds, drift-triggered repartition, persistence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import streaming
from repro.checkpoint.manager import CheckpointManager
from repro.core import simple_lsh, topk
from repro.core.bucket_index import build_buckets
from repro.core.engine import bucket_candidates, dense_candidates
from repro.data.synthetic import make_dataset
from repro.kernels import ops, ref


@pytest.fixture(scope="module")
def ds():
    return make_dataset("imagenet", jax.random.PRNGKey(0), n=500, d=16,
                        num_queries=6)


@pytest.fixture(scope="module")
def pool(ds):
    """Held-out insert pool with the same norm profile."""
    extra = make_dataset("imagenet", jax.random.PRNGKey(9), n=200, d=16,
                         num_queries=1)
    return np.asarray(extra.items)


def rebuild_candidates(mi, queries, num_probe, engine="bucket",
                       impl="ref"):
    """Oracle: rebuild a bucket store from scratch over the mutated live
    set (frozen hashes, current bounds) via the *core* build path, then
    run the core engine and map back to global ids."""
    rows = np.flatnonzero(mi._live)
    n = mi.delta.count
    slots = np.flatnonzero(mi.delta._live[:n])
    codes = np.concatenate([mi._codes[rows], mi.delta._codes[slots]])
    rid = np.concatenate([mi._rid[rows], mi.delta._rid[slots]])
    gids = np.concatenate([rows, mi.store_size + slots]).astype(np.int32)
    b = build_buckets(jnp.asarray(codes), jnp.asarray(rid),
                      jnp.asarray(mi.upper), mi.hash_bits, mi.eps)
    q_codes = mi.encode_queries(queries)
    if engine == "bucket":
        local = bucket_candidates(b, q_codes, num_probe, impl=impl)
    else:
        local = dense_candidates(b, q_codes, jnp.asarray(codes),
                                 jnp.asarray(rid), num_probe, impl=impl)
    return gids[np.asarray(local)]


def assert_parity(mi, queries, num_probe, impl="ref"):
    for engine in ("bucket", "dense"):
        mi.engine = engine
        got = np.asarray(mi.candidates(queries, num_probe))
        want = rebuild_candidates(mi, queries, num_probe, engine, impl)
        np.testing.assert_array_equal(got, want)
    mi.engine = "auto"


def assert_codes_invariant(mi):
    """Every live item's stored code equals a fresh encode under the
    current bounds — repartition kept hashes semantically valid."""
    rows = np.flatnonzero(mi._live)
    fresh = mi._encode(mi.items[jnp.asarray(rows)], mi._rid[rows])
    np.testing.assert_array_equal(mi._codes[rows], fresh)
    n = mi.delta.count
    slots = np.flatnonzero(mi.delta._live[:n])
    if slots.size:
        fresh = mi._encode(mi.delta.items[jnp.asarray(slots)],
                           mi.delta._rid[slots])
        np.testing.assert_array_equal(mi.delta._codes[slots], fresh)


# -- delta-scan kernel -------------------------------------------------------


@pytest.mark.parametrize("q,c,w", [(8, 64, 1), (37, 130, 2), (64, 128, 4),
                                   (1, 1, 1)])
def test_delta_scan_matches_ref(q, c, w):
    k1, k2 = jax.random.PRNGKey(q), jax.random.PRNGKey(c)
    qc = jax.random.bits(k1, (q, w), jnp.uint32)
    dc = jax.random.bits(k2, (c, w), jnp.uint32)
    live = jax.random.bernoulli(jax.random.PRNGKey(w), 0.5, (c,))
    got = ops.delta_scan(qc, dc, live, 32 * w, impl="pallas")
    want = ref.delta_scan_ref(qc, dc, live, 32 * w)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    dead = ~np.asarray(live)
    assert np.all(np.asarray(got)[:, dead] == -1)


# -- merge parity (the acceptance criterion) ---------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("impl", ["ref", "pallas"])
@pytest.mark.parametrize("kind", ["range", "simple"])
def test_parity_any_interleaving(ds, pool, kind, impl):
    """For an interleaving of inserts and deletes (base and delta ids,
    overflow norms included), merged (base + delta) candidates are
    identical to a from-scratch rebuild on the mutated dataset — both
    engines, ref and pallas, RangeLSH and SimpleLSH."""
    if kind == "range":
        mi = streaming.build(ds.items, jax.random.PRNGKey(1), 12, 8,
                             capacity=64, max_tombstones=16, impl=impl)
    else:
        si = simple_lsh.build(ds.items, jax.random.PRNGKey(1), 12)
        mi = streaming.MutableIndex.from_simple_lsh(
            si, capacity=64, max_tombstones=16, impl=impl)
    probes = (20, 111) if impl == "ref" else (33,)
    for p in probes:
        assert_parity(mi, ds.queries, p, impl)
    ids1 = mi.insert(pool[:30])
    mi.delete([0, 7, 13, int(ids1[4]), int(ids1[20])])
    big = pool[:1] / np.linalg.norm(pool[:1]) * float(mi.upper.max()) * 2.5
    mi.insert(big)                                    # overflow event
    mi.delete(ids1[5:9].tolist())
    mi.insert(pool[30:45])
    for p in probes:
        assert_parity(mi, ds.queries, p, impl)
    assert_codes_invariant(mi)
    before = np.asarray(mi.candidates(ds.queries, probes[0]))
    mi.compact()                                      # results unchanged
    np.testing.assert_array_equal(
        before, np.asarray(mi.candidates(ds.queries, probes[0])))
    assert_parity(mi, ds.queries, probes[0], impl)


def test_full_budget_query_is_exact(ds, pool):
    """num_probe == live count covers everything: streaming query equals
    exact MIPS over the mutated live set."""
    mi = streaming.build(ds.items, jax.random.PRNGKey(1), 12, 8,
                         capacity=64)
    ids = mi.insert(pool[:40])
    mi.delete([2, 3, int(ids[0])])
    live_vecs, gids = mi.live_vectors()
    ev, ei = topk.exact_mips(ds.queries, live_vecs, 5)
    sv, si = mi.query(ds.queries, 5, mi.live_count)
    np.testing.assert_allclose(np.asarray(sv), np.asarray(ev), atol=1e-4)
    np.testing.assert_array_equal(np.asarray(si), gids[np.asarray(ei)])


# -- delta-buffer edge cases -------------------------------------------------


def test_empty_delta_matches_base_engine(ds):
    """Fresh index (empty delta): merged candidates equal the immutable
    core engine's output on the same store."""
    from repro.core import range_lsh
    from repro.core.bucket_index import build_bucket_index
    from repro.core.engine import QueryEngine

    idx = range_lsh.build(ds.items, jax.random.PRNGKey(1), 12, 8)
    mi = streaming.MutableIndex.from_range_lsh(idx, capacity=32)
    eng = QueryEngine(idx, engine="bucket",
                      buckets=build_bucket_index(idx))
    np.testing.assert_array_equal(
        np.asarray(mi.candidates(ds.queries, 64)),
        np.asarray(eng.candidates(ds.queries, 64)))


def test_full_delta_auto_compacts(ds, pool):
    """Hitting capacity folds the delta automatically; ids stay stable
    and parity holds across the fold."""
    mi = streaming.build(ds.items, jax.random.PRNGKey(1), 12, 4,
                         capacity=16)
    ids = []
    for i in range(0, 48, 8):
        ids.append(mi.insert(pool[i:i + 8]))
    ids = np.concatenate(ids)
    assert mi.num_compactions >= 2
    assert len(np.unique(ids)) == 48          # ids never reused
    assert mi.delta.count <= mi.capacity
    # every id resolves: delete half of them, then parity
    mi.delete(ids[::2].tolist())
    assert_parity(mi, ds.queries, 40)
    # a single over-capacity batch gets chunked
    big_ids = mi.insert(pool[48:48 + 24])
    assert big_ids.shape == (24,) and len(np.unique(big_ids)) == 24
    assert_parity(mi, ds.queries, 40)


def test_delete_batch_is_atomic(ds):
    """A bad id rejects the whole batch: nothing tombstoned, mirrors in
    sync, and the valid ids remain deletable on retry."""
    mi = streaming.build(ds.items, jax.random.PRNGKey(1), 12, 4,
                         capacity=16)
    with pytest.raises(KeyError):
        mi.delete([5, 10 ** 7])
    assert mi._live[5] and mi.tomb_csr == 0
    with pytest.raises(ValueError):
        mi.delete([5, 5])
    mi.delete([5])              # retry of the valid id succeeds
    assert not mi._live[5]
    assert_parity(mi, ds.queries, 40)


def test_all_tombstoned_range(ds):
    """Deleting every item of one range leaves a live, parity-exact index
    that never emits the dead range's items."""
    mi = streaming.build(ds.items, jax.random.PRNGKey(1), 12, 4,
                         capacity=32, max_tombstones=500)
    victims = np.flatnonzero(mi._rid == 2)
    mi.delete(victims.tolist())
    assert int(self_counts := mi.monitor.counts[2]) == 0, self_counts
    cand = np.asarray(mi.candidates(ds.queries, mi.live_count))
    assert not np.isin(cand, victims).any()
    assert_parity(mi, ds.queries, 50)


def test_insert_into_empty_uniform_bin(ds):
    """Uniform partitioning leaves empty bins (long-tail norms); the first
    insert into one raises its bound from zero (bin_init drift event) and
    stays parity-exact."""
    mi = streaming.build(ds.items, jax.random.PRNGKey(1), 12, 16,
                         scheme="uniform", capacity=32)
    empty = np.flatnonzero(mi._count_live() == 0)
    assert empty.size, "long-tail norms should leave empty uniform bins"
    j = int(empty[0])
    lo = float(mi.edges[j - 1]) if j else 0.0
    hi = float(mi.edges[j]) if j < mi.num_ranges - 1 else float(
        mi.upper.max())
    target = (lo + hi) / 2
    v = np.ones((1, 16), np.float32)
    v = v / np.linalg.norm(v) * target
    assert mi.upper[j] == 0.0
    ids = mi.insert(v)
    assert any(e["kind"] == "bin_init" and e["range"] == j
               for e in mi.events)
    assert mi.upper[j] == pytest.approx(target, rel=1e-5)
    assert int(mi.delta._rid[0]) == j
    assert_parity(mi, ds.queries, 50)
    # the new item is findable: full-budget probe must include it
    cand = np.asarray(mi.candidates(ds.queries, mi.live_count))
    assert np.isin(ids[0], cand).all()


# -- drift-triggered repartition ---------------------------------------------


def test_overflow_triggers_localized_repartition(ds):
    mi = streaming.build(ds.items, jax.random.PRNGKey(1), 12, 8,
                         capacity=32)
    top = int(np.argmax(mi.upper))
    v = np.ones((1, 16), np.float32)
    v = v / np.linalg.norm(v) * float(mi.upper[top]) * 3.0
    mi.insert(v)
    ev = [e for e in mi.events if e["kind"] == "overflow_localized"]
    assert len(ev) == 1 and ev[0]["range"] == top
    assert mi.num_repartitions == 1 and mi.num_full_rebuilds == 0
    assert mi.upper[top] == pytest.approx(
        float(np.linalg.norm(v)), rel=1e-5)
    assert_codes_invariant(mi)
    assert_parity(mi, ds.queries, 50)


def test_repartition_policy_full_rebuilds(ds):
    mi = streaming.build(ds.items, jax.random.PRNGKey(1), 12, 8,
                         capacity=32, repartition_policy="full")
    v = np.ones((1, 16), np.float32) * float(mi.upper.max())
    mi.insert(v)
    assert mi.num_full_rebuilds == 1 and mi.num_repartitions == 0
    assert_codes_invariant(mi)
    assert_parity(mi, ds.queries, 50)


def test_skew_triggers_rebalance(ds):
    mi = streaming.build(ds.items, jax.random.PRNGKey(1), 12, 4,
                         capacity=512, skew_ratio=1.5, min_skew_count=50)
    med = float(np.median(mi._norms))
    rng = np.random.default_rng(3)
    dirs = rng.normal(size=(300, 16)).astype(np.float32)
    dirs /= np.linalg.norm(dirs, axis=1, keepdims=True)
    mi.insert(dirs * med)                  # pile into one range
    ev = [e for e in mi.events if e["kind"] == "skew_rebalance"]
    assert ev, "occupancy skew should trigger a rebalance"
    counts = mi.monitor.counts
    assert counts.max() <= mi.monitor.skew_ratio * counts.sum() / 4 * 1.5
    assert_codes_invariant(mi)
    assert_parity(mi, ds.queries, 60)


def test_unsplittable_skew_is_muted(ds):
    """A skewed range whose members all share one norm can't be split;
    the failed rebalance is muted (one O(N) attempt, not one per insert)
    until the next structural event."""
    rng = np.random.default_rng(5)
    dirs = rng.normal(size=(200, 16)).astype(np.float32)
    dirs /= np.linalg.norm(dirs, axis=1, keepdims=True)   # all norm 1
    mi = streaming.build(jnp.asarray(dirs), jax.random.PRNGKey(1), 12, 4,
                         capacity=512, skew_ratio=1.2, min_skew_count=20)
    extra = rng.normal(size=(80, 16)).astype(np.float32)
    extra /= np.linalg.norm(extra, axis=1, keepdims=True)
    mi.insert(extra[:40])
    blocked = [e for e in mi.events if e["kind"] == "rebalance_blocked"]
    assert len(blocked) == 1
    mi.insert(extra[40:])                  # muted: no second attempt
    blocked = [e for e in mi.events if e["kind"] == "rebalance_blocked"]
    assert len(blocked) == 1
    assert_parity(mi, ds.queries, 60)
    mi.compact()                           # structural event re-arms
    assert not mi._skew_muted


def test_monitor_quantiles_report_drift(ds):
    mi = streaming.build(ds.items, jax.random.PRNGKey(1), 12, 4,
                         capacity=256)
    hi = float(mi.upper.max())
    rng = np.random.default_rng(4)
    dirs = rng.normal(size=(64, 16)).astype(np.float32)
    dirs /= np.linalg.norm(dirs, axis=1, keepdims=True)
    mi.insert(dirs * hi * 0.99)            # fatten the tail, no overflow
    snap = mi.monitor.snapshot()
    top = mi.num_ranges - 1
    assert snap["recent_q95_over_baseline"][top] > 1.0


# -- persistence -------------------------------------------------------------


def test_checkpoint_mount_roundtrip(ds, pool, tmp_path):
    """save -> load mounts the index without a rebuild: identical queries,
    identical behavior under further mutation."""
    mi = streaming.build(ds.items, jax.random.PRNGKey(1), 12, 8,
                         capacity=64)
    ids = mi.insert(pool[:20])
    mi.delete([1, 2, int(ids[3])])
    mgr = CheckpointManager(str(tmp_path))
    streaming.save_index(mgr, 7, mi)
    loaded = streaming.load_index(str(tmp_path))
    assert loaded.live_count == mi.live_count
    assert loaded.tomb_csr == mi.tomb_csr
    np.testing.assert_array_equal(
        np.asarray(loaded.candidates(ds.queries, 80)),
        np.asarray(mi.candidates(ds.queries, 80)))
    # identical mutations diverge nowhere
    i1, i2 = mi.insert(pool[20:25]), loaded.insert(pool[20:25])
    np.testing.assert_array_equal(i1, i2)
    mi.delete([int(i1[0])])
    loaded.delete([int(i2[0])])
    np.testing.assert_array_equal(
        np.asarray(loaded.candidates(ds.queries, 80)),
        np.asarray(mi.candidates(ds.queries, 80)))
    assert_parity(loaded, ds.queries, 80)


def test_load_missing_checkpoint_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        streaming.load_index(str(tmp_path))


def test_calibration_roundtrip_and_drift_invalidation(ds, pool, tmp_path):
    """Planner calibration (DESIGN.md §12) survives save/mount — the
    mounted index honors the same recall contract — and a drift-triggered
    repartition that moves range boundaries flags it stale on both the
    live index and snapshots taken afterwards."""
    from repro.core import planner

    mi = streaming.build(ds.items, jax.random.PRNGKey(1), 12, 8,
                         capacity=64)
    mi.set_calibration(planner.calibrate_streaming(mi, ds.queries, k=5))
    mgr = CheckpointManager(str(tmp_path))
    streaming.save_index(mgr, 1, mi)
    loaded = streaming.load_index(str(tmp_path))
    assert loaded.calib is not None and not loaded.calib_stale
    np.testing.assert_array_equal(loaded.calib.probe_grid,
                                  mi.calib.probe_grid)
    np.testing.assert_allclose(loaded.calib.recall_range,
                               mi.calib.recall_range)
    np.testing.assert_allclose(loaded.calib.truth_mass,
                               mi.calib.truth_mass)
    assert loaded.calib.k == mi.calib.k
    v1 = mi.query(ds.queries, 5, recall_target=0.8)
    v2 = loaded.query(ds.queries, 5, recall_target=0.8)
    np.testing.assert_array_equal(np.asarray(v1[1]), np.asarray(v2[1]))

    # overflow insert -> localized repartition moves a range boundary
    hi = np.zeros((1, mi.items.shape[1]), np.float32)
    hi[0, 0] = float(mi.upper.max()) * 2.0
    mi.insert(jnp.asarray(hi))
    assert mi.calib_stale
    assert any(e["kind"] == "calibration_stale" for e in mi.events)
    streaming.save_index(mgr, 2, mi)
    reloaded = streaming.load_index(str(tmp_path), step=2)
    assert reloaded.calib is not None
    assert reloaded.calib_stale, \
        "staleness must survive the checkpoint round-trip"
    with pytest.raises(ValueError, match="stale"):
        reloaded.query(ds.queries, 5, recall_target=0.8)

    # pre-planner snapshots (step 1 was saved calibrated; simulate by
    # mounting an old-layout tree) still mount with calib=None
    old = streaming.build(ds.items[:100], jax.random.PRNGKey(2), 12, 4,
                          capacity=32)
    streaming.save_index(mgr, 3, old)
    assert streaming.load_index(str(tmp_path), step=3).calib is None


# -- typed guard exceptions (repro-lint R1: checks must survive -O) -----------


def _tiny_delta():
    from repro.streaming.delta import DeltaBuffer
    buf = DeltaBuffer(capacity=4, dim=2, words=1)
    buf.append(jnp.ones((2, 2)), np.ones((2,), np.float32),
               np.zeros((2, 1), np.uint32), np.zeros((2,), np.int32),
               np.arange(2, dtype=np.int32), [])
    return buf


def test_delta_overflow_raises_value_error():
    buf = _tiny_delta()
    with pytest.raises(ValueError, match="delta buffer overflow"):
        buf.append(jnp.ones((3, 2)), np.ones((3,), np.float32),
                   np.zeros((3, 1), np.uint32), np.zeros((3,), np.int32),
                   np.arange(3, dtype=np.int32), [])
    assert buf.count == 2, "failed append must not mutate the buffer"


def test_delta_tombstone_out_of_range_raises_index_error():
    buf = _tiny_delta()
    for slot in (-1, 2, 7):
        with pytest.raises(IndexError, match="outside the occupied"):
            buf.tombstone(slot)


def test_delta_double_tombstone_raises_value_error():
    buf = _tiny_delta()
    buf.tombstone(1)
    with pytest.raises(ValueError, match="already tombstoned"):
        buf.tombstone(1)
    assert buf.live_count == 1
