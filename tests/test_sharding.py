"""Sharding rules: every arch's param tree gets rank-consistent specs and
the production-mesh dimensions divide (or pad legally)."""

import functools

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import ARCH_IDS, get_config
from repro.models import lm
from repro.parallel import sharding as shd
from repro.parallel.analytic import estimate, matmul_param_counts
from repro.configs.base import SHAPES


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_rank_match(arch):
    """Spec length == leaf rank for every parameter of every arch (full
    config, abstract — no allocation)."""
    cfg = get_config(arch)
    params = jax.eval_shape(
        functools.partial(lm.init_params, cfg=cfg), jax.random.PRNGKey(0))
    for mode in ({"fsdp_axis": "data"}, {"fsdp_axis": None},
                 {"fsdp_axis": None, "serve_stationary": True}):
        specs = shd.param_specs(params, cfg, **mode)
        flat_p = jax.tree_util.tree_leaves(params)
        flat_s = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        assert len(flat_p) == len(flat_s)
        for leaf, spec in zip(flat_p, flat_s):
            assert len(spec) == leaf.ndim, (arch, leaf.shape, spec)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_model_axis_dims_shardable(arch):
    """Dims mapped to the 16-way model axis are multiples of 16 or vocab
    (padded to 256). GSPMD tolerates remainders, but the production rules
    should not rely on it for the big tensors."""
    cfg = get_config(arch)
    params = jax.eval_shape(
        functools.partial(lm.init_params, cfg=cfg), jax.random.PRNGKey(0))
    specs = shd.param_specs(params, cfg, fsdp_axis="data")
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    sflat = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    for (path, leaf), spec in zip(flat, sflat):
        for dim, axis in enumerate(spec):
            if axis == "model" and leaf.shape[dim] >= 256:
                assert leaf.shape[dim] % 16 == 0, (path, leaf.shape, spec)


def test_dp_axes_for_batch():
    from repro.launch.mesh import make_compat_mesh
    mesh = make_compat_mesh((1, 1), ("data", "model"))
    assert shd.dp_axes_for_batch(mesh, 1) == ("data",)
    # a fake mesh-shape check via the sharding helper contract:
    # batch=1 on a 16-way axis must not be sharded
    from repro.launch.mesh import make_local_mesh
    m = make_local_mesh()
    assert shd.dp_axes_for_batch(m, None) == ("data",)


@pytest.mark.parametrize("arch", ["jamba_1_5_large_398b",
                                  "llama4_scout_17b_a16e"])
def test_param_counts_match_published(arch):
    cfg = get_config(arch)
    params = jax.eval_shape(
        functools.partial(lm.init_params, cfg=cfg), jax.random.PRNGKey(0))
    total = sum(x.size for x in jax.tree.leaves(params))
    if arch == "jamba_1_5_large_398b":
        assert 380e9 < total < 420e9          # published: 398B
        counts = matmul_param_counts(cfg, params)
        active = total - counts["expert"] * (1 - 2 / 16)
        assert 85e9 < active < 105e9          # published: 94B active
    else:
        assert 80e9 < total < 130e9           # 17B active x 16E + shared


@pytest.mark.parametrize("shape_name", ["train_4k", "decode_32k"])
def test_analytic_estimator_sane(shape_name):
    cfg = get_config("qwen2_1_5b")
    params = jax.eval_shape(
        functools.partial(lm.init_params, cfg=cfg), jax.random.PRNGKey(0))
    est = estimate(cfg, SHAPES[shape_name], params, chips=256)
    assert est["flops"] > 0 and est["hbm_bytes_per_device"] > 0
    assert est["model_flops"] <= est["flops"] * 1.001
    if shape_name == "train_4k":
        # 6ND sanity: within 2x of the classic estimate
        six_nd = 6 * est["matmul_active"] * est["tokens"]
        assert 0.5 < est["matmul_flops"] / six_nd < 2.0