"""Fault-tolerance policies: heartbeats, stragglers, elastic recovery."""

import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.launch.runtime import (HeartbeatTracker, StragglerEvent,
                                  StragglerMonitor, WorkerFailure,
                                  elastic_recover)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_heartbeat_failure_detection():
    clock = FakeClock()
    hb = HeartbeatTracker(["w0", "w1", "w2"], timeout_s=10.0, clock=clock)
    clock.t = 5.0
    hb.beat("w0")
    hb.beat("w1")
    clock.t = 12.0
    assert hb.failed() == ["w2"]
    with pytest.raises(WorkerFailure) as ei:
        hb.check()
    assert ei.value.workers == ["w2"]
    hb.beat("w2")
    assert hb.failed() == []


def test_straggler_monitor_escalates_after_consecutive():
    clock = FakeClock()
    mon = StragglerMonitor(deadline_s=1.0, max_consecutive=2, clock=clock)

    def slow_step(step):
        with mon.step(step):
            clock.t += 5.0

    slow_step(0)
    assert mon.slow_steps == [0]
    with pytest.raises(StragglerEvent):
        slow_step(1)
    # a fast step resets the consecutive counter
    with mon.step(2):
        clock.t += 0.1
    slow_step(3)
    assert mon.slow_steps == [0, 1, 3]


def test_straggler_monitor_disabled():
    mon = StragglerMonitor(deadline_s=None)
    with mon.step(0):
        pass
    assert mon.slow_steps == []


def test_elastic_recover_restores_state(tmp_path):
    """Pod loss: re-mesh from surviving slices + restore latest step.
    On this 1-device host the elastic mesh is (1, 1); the contract tested
    is mesh rebuild + bit-exact state restore."""
    state = {"w": jnp.arange(8, dtype=jnp.float32).reshape(2, 4),
             "step": jnp.asarray(42, jnp.int32)}
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(42, state)
    mesh, step, restored = elastic_recover(
        mgr, state, surviving_slices=1, slice_shape=(1, 1))
    assert step == 42
    assert mesh.axis_names == ("data", "model")
    assert bool((restored["w"] == state["w"]).all())


def test_elastic_recover_requires_checkpoint(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    with pytest.raises(RuntimeError):
        elastic_recover(mgr, {}, surviving_slices=1, slice_shape=(1, 1))
