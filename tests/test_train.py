"""Training loop: convergence smoke, checkpoint-resume, compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.data.tokens import SyntheticCorpus
from repro.launch.mesh import make_local_mesh
from repro.launch.train import (TrainHParams, init_state, make_train_step,
                                run_training)
from repro.optim.compression import bf16_compress, ef_init
from repro.optim.optimizers import (adamw_init, adamw_update,
                                    clip_by_global_norm, cosine_schedule)


def test_loss_decreases_over_steps():
    cfg = get_config("qwen3_0_6b").reduced()
    mesh = make_local_mesh()
    hp = TrainHParams(lr=1e-3, warmup=2, total_steps=20)
    state = init_state(jax.random.PRNGKey(0), cfg)
    step_fn = make_train_step(cfg, mesh, hp)
    corpus = SyntheticCorpus(cfg.vocab, 16)
    losses = []
    for s in range(8):
        batch = dict(corpus.sample(s, 0, 4)._asdict())
        state, m = step_fn(state, batch, jnp.asarray(s, jnp.int32))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    assert all(np.isfinite(losses))


def test_run_training_checkpoint_resume(tmp_path):
    """Driver restartability: run 4 steps w/ checkpointing, then resume —
    the resumed run continues from the checkpointed step."""
    cfg = get_config("qwen3_0_6b").reduced()
    mesh = make_local_mesh()
    hp = TrainHParams(lr=1e-3, warmup=2, total_steps=10)
    seen = []
    run_training(cfg, mesh, hp, global_batch=2, seq_len=16, steps=4,
                 ckpt_dir=str(tmp_path), ckpt_every=2,
                 on_metrics=lambda s, m: seen.append(s), log_every=1)
    # "crash" and resume: starts at the checkpointed step 4 and runs to 6
    seen2 = []
    run_training(cfg, mesh, hp, global_batch=2, seq_len=16, steps=6,
                 ckpt_dir=str(tmp_path), ckpt_every=2,
                 on_metrics=lambda s, m: seen2.append(s), log_every=1)
    assert seen2[0] >= 4


def test_adamw_moves_params_toward_lower_loss():
    params = {"w": jnp.asarray([2.0, -3.0], jnp.float32)}
    state = adamw_init(params)

    def loss(p):
        return jnp.sum(jnp.square(p["w"]))

    for s in range(60):
        g = jax.grad(loss)(params)
        params, state = adamw_update(g, state, params,
                                     lr=jnp.asarray(0.1),
                                     weight_decay=0.0)
    assert float(loss(params)) < 0.05


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0,
                                                                 rel=1e-3)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=100)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert float(lr(jnp.asarray(10))) == pytest.approx(1.0, abs=1e-3)
    assert float(lr(jnp.asarray(100))) == pytest.approx(0.0, abs=1e-3)


def test_bf16_compression_error_feedback_converges():
    """Error feedback: the accumulated compression error stays bounded and
    the mean compressed gradient tracks the true gradient."""
    g = {"w": jnp.full((1000,), 0.001, jnp.float32)}  # below bf16 grid step?
    ef = ef_init(g)
    total = jnp.zeros((1000,))
    for _ in range(50):
        comp, ef = bf16_compress(g, ef)
        total = total + comp["w"].astype(jnp.float32)
    mean = total / 50
    np.testing.assert_allclose(np.asarray(mean), 0.001, rtol=1e-2)
    assert float(jnp.abs(ef.residual["w"]).max()) < 0.001
