"""Property tests for norm-range partitioning (Algorithm 1 invariants)."""

import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core.partition import (effective_upper, percentile_partition,
                                  single_partition, uniform_partition)


@given(st.integers(10, 400), st.integers(1, 16), st.booleans())
def test_percentile_partition_invariants(n, m, with_ties):
    rng = np.random.default_rng(n * 31 + m)
    norms = rng.lognormal(0.0, 1.0, n).astype(np.float32)
    if with_ties:
        norms[: n // 2] = norms[0]        # heavy ties (Algorithm 1 note)
    part = percentile_partition(jnp.asarray(norms), m)
    rid = np.asarray(part.range_id)
    counts = np.asarray(part.counts)
    # (1) every item assigned to a valid range; counts consistent
    assert rid.min() >= 0 and rid.max() < m
    assert counts.sum() == n
    np.testing.assert_array_equal(counts, np.bincount(rid, minlength=m))
    # (2) percentile slabs are balanced within 1
    assert counts.max() - counts.min() <= 1
    # (3) ranges are norm-ordered: max norm of range j <= min norm of the
    # next NON-EMPTY range (m > n leaves empty trailing ranges)
    upper = np.asarray(part.upper)
    lower = np.asarray(part.lower)
    occupied = [j for j in range(m) if counts[j] > 0]
    for a, b in zip(occupied, occupied[1:]):
        assert upper[a] <= lower[b] + 1e-6
    # (4) upper/lower are true extrema
    for j in range(m):
        sel = norms[rid == j]
        if sel.size:
            assert abs(upper[j] - sel.max()) < 1e-6
            assert abs(lower[j] - sel.min()) < 1e-6


@given(st.integers(10, 300), st.integers(1, 12))
def test_uniform_partition_invariants(n, m):
    rng = np.random.default_rng(n * 13 + m)
    norms = rng.lognormal(0.0, 0.8, n).astype(np.float32)
    part = uniform_partition(jnp.asarray(norms), m)
    rid = np.asarray(part.range_id)
    assert rid.min() >= 0 and rid.max() < m
    assert np.asarray(part.counts).sum() == n
    # uniform bins: same-bin items are within one bin width
    width = (norms.max() - norms.min()) / m + 1e-6
    for j in np.unique(rid):
        sel = norms[rid == j]
        assert sel.max() - sel.min() <= width + 1e-4


def test_single_partition_is_simple_lsh():
    norms = jnp.asarray([1.0, 2.0, 0.5, 3.0])
    part = single_partition(norms)
    assert part.num_ranges == 1
    assert float(part.upper[0]) == 3.0
    assert int(part.counts[0]) == 4


def test_effective_upper_fills_empty_ranges():
    norms = jnp.asarray([1.0, 1.0, 1.0, 5.0])
    part = uniform_partition(norms, 8)     # middle bins empty
    upper = effective_upper(part)
    assert bool(jnp.all(upper > 0))
