"""Property tests for norm-range partitioning (Algorithm 1 invariants)."""

import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core.partition import (effective_upper, percentile_partition,
                                  single_partition, uniform_partition)


@given(st.integers(10, 400), st.integers(1, 16), st.booleans())
def test_percentile_partition_invariants(n, m, with_ties):
    rng = np.random.default_rng(n * 31 + m)
    norms = rng.lognormal(0.0, 1.0, n).astype(np.float32)
    if with_ties:
        norms[: n // 2] = norms[0]        # heavy ties (Algorithm 1 note)
    part = percentile_partition(jnp.asarray(norms), m)
    rid = np.asarray(part.range_id)
    counts = np.asarray(part.counts)
    # (1) every item assigned to a valid range; counts consistent
    assert rid.min() >= 0 and rid.max() < m
    assert counts.sum() == n
    np.testing.assert_array_equal(counts, np.bincount(rid, minlength=m))
    # (2) percentile slabs are balanced within 1
    assert counts.max() - counts.min() <= 1
    # (3) ranges are norm-ordered: max norm of range j <= min norm of the
    # next NON-EMPTY range (m > n leaves empty trailing ranges)
    upper = np.asarray(part.upper)
    lower = np.asarray(part.lower)
    occupied = [j for j in range(m) if counts[j] > 0]
    for a, b in zip(occupied, occupied[1:]):
        assert upper[a] <= lower[b] + 1e-6
    # (4) upper/lower are true extrema
    for j in range(m):
        sel = norms[rid == j]
        if sel.size:
            assert abs(upper[j] - sel.max()) < 1e-6
            assert abs(lower[j] - sel.min()) < 1e-6


@given(st.integers(10, 300), st.integers(1, 12))
def test_uniform_partition_invariants(n, m):
    rng = np.random.default_rng(n * 13 + m)
    norms = rng.lognormal(0.0, 0.8, n).astype(np.float32)
    part = uniform_partition(jnp.asarray(norms), m)
    rid = np.asarray(part.range_id)
    assert rid.min() >= 0 and rid.max() < m
    assert np.asarray(part.counts).sum() == n
    # uniform bins: same-bin items are within one bin width
    width = (norms.max() - norms.min()) / m + 1e-6
    for j in np.unique(rid):
        sel = norms[rid == j]
        assert sel.max() - sel.min() <= width + 1e-4


def test_single_partition_is_simple_lsh():
    norms = jnp.asarray([1.0, 2.0, 0.5, 3.0])
    part = single_partition(norms)
    assert part.num_ranges == 1
    assert float(part.upper[0]) == 3.0
    assert int(part.counts[0]) == 4


def test_effective_upper_fills_empty_ranges():
    norms = jnp.asarray([1.0, 1.0, 1.0, 5.0])
    part = uniform_partition(norms, 8)     # middle bins empty
    upper = effective_upper(part)
    assert bool(jnp.all(upper > 0))


def test_uniform_partition_empty_bins_stats():
    """Two norm clusters at the domain ends: interior bins are empty with
    zeroed stats, and effective_upper substitutes the global max for every
    empty bin (so no downstream division by zero)."""
    norms = jnp.asarray([1.0, 1.01, 1.02, 9.0, 9.01, 9.02])
    m = 10
    part = uniform_partition(norms, m)
    counts = np.asarray(part.counts)
    upper = np.asarray(part.upper)
    lower = np.asarray(part.lower)
    assert counts.sum() == 6
    empty = counts == 0
    assert empty.any() and not empty[0] and not empty[-1]
    # empty bins report 0 for both extrema
    assert np.all(upper[empty] == 0.0)
    assert np.all(lower[empty] == 0.0)
    # occupied bins keep true extrema
    assert np.all(upper[~empty] > 0.0)
    eff = np.asarray(effective_upper(part))
    assert np.all(eff[empty] == np.max(norms))
    np.testing.assert_array_equal(eff[~empty], upper[~empty])


def test_index_bits_budget_accounting():
    """§4 code-budget split: ceil(log2 m) bits for the sub-dataset id,
    including m=1 (no id needed) and non-power-of-two m."""
    from repro.core.range_lsh import index_bits

    assert index_bits(1) == 0
    assert index_bits(2) == 1
    assert index_bits(3) == 2          # non-power-of-two rounds up
    assert index_bits(4) == 2
    assert index_bits(5) == 3
    assert index_bits(31) == 5
    assert index_bits(32) == 5
    assert index_bits(33) == 6


def test_charge_index_bits_budget_in_build():
    """charge_index_bits=True spends the id bits out of code_len; False
    gives the full budget to hashing (the ablation mode)."""
    import jax

    from repro.core import range_lsh

    rng = np.random.default_rng(0)
    items = jnp.asarray(rng.normal(size=(200, 16)).astype(np.float32))
    key = jax.random.PRNGKey(0)
    for m in (1, 5, 8):                # m=1 and non-power-of-two included
        idx = range_lsh.build(items, key, 32, m)
        assert idx.hash_bits == 32 - range_lsh.index_bits(m)
        assert idx.code_len == 32
        assert idx.codes.shape == (200, (idx.hash_bits + 31) // 32)
        free = range_lsh.build(items, key, 32, m, charge_index_bits=False)
        assert free.hash_bits == 32
    # budget too small for the id bits: build must refuse
    with np.testing.assert_raises(ValueError):
        range_lsh.build(items, key, 3, 8)   # index_bits(8)=3 => 0 hash bits
