"""Planner conformance suite (DESIGN.md §12): property-based cross-engine
invariants over long-tail norm distributions.

Hypothesis generates lognormal/Zipf norm mixtures (the paper's Fig-1
long-tail profiles) and the tests assert the planner's contract surface:

  * plans for increasing recall targets are *nested* — per-range budgets
    grow elementwise and the planned candidate set of a smaller target is
    an order-preserving subset of a larger target's;
  * bucket, dense and distributed execution of the same budgets return
    identical candidate ids (the per-range-prefix contract is engine
    independent);
  * measured recall against brute-force ground truth meets the planner's
    predicted recall (exactly on the calibration sample, within sampling
    tolerance held-out).

Runs under real hypothesis in CI (including the 8-forced-host-device step,
where the distributed invariant exercises real ``shard_map`` collectives);
under the deterministic fallback shim (conftest.py) the same properties
replay on a fixed sample grid and skip-annotate rather than silently pass
if a strategy cannot be sampled.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import distributed, planner, topk
from repro.core.engine import QueryEngine
from repro.core.index import IndexSpec, build
from repro.launch.mesh import make_local_mesh

SETTINGS = dict(max_examples=4, deadline=None,
                suppress_health_check=list(HealthCheck))

TARGETS = (0.5, 0.8, 0.95)


@st.composite
def longtail_params(draw):
    """Long-tail dataset parameters (Fig 1b): a lognormal body mixed with
    a Zipf/Pareto tail, plus index shape knobs."""
    return dict(
        n=draw(st.integers(250, 450)),
        d=draw(st.integers(8, 16)),
        sigma=draw(st.floats(0.4, 1.1)),
        zipf_a=draw(st.floats(1.5, 3.5)),
        mix=draw(st.floats(0.3, 0.9)),
        m=draw(st.sampled_from([4, 8])),
        seed=draw(st.integers(0, 2 ** 16)),
    )


def make_longtail(p, num_queries=64):
    """(items, queries) with mixed lognormal/Zipf norms."""
    rng = np.random.default_rng(p["seed"])
    n, d = p["n"], p["d"]
    ln = rng.lognormal(0.0, p["sigma"], n)
    zf = (1.0 / (1.0 - rng.uniform(0.0, 0.99, n))) ** (1.0 / p["zipf_a"])
    norms = np.where(rng.uniform(0.0, 1.0, n) < p["mix"], ln, zf)
    dirs = rng.normal(size=(n, d))
    dirs /= np.linalg.norm(dirs, axis=1, keepdims=True)
    items = jnp.asarray(dirs * norms[:, None], jnp.float32)
    queries = jnp.asarray(rng.normal(size=(num_queries, d)), jnp.float32)
    return items, queries


def build_calibrated(p, family="simple", extra_queries=0):
    items, queries = make_longtail(p, num_queries=64 + extra_queries)
    spec = IndexSpec(family=family, code_len=16, m=p["m"],
                     charge_index_bits=False)
    cidx = build(spec, items, jax.random.PRNGKey(p["seed"] % 97),
                 calibration_queries=queries[:64])
    return cidx, queries


def assert_ordered_subset(small: np.ndarray, big: np.ndarray):
    """Every row of ``small`` is an order-preserving subset of ``big``."""
    for s_row, b_row in zip(small, big):
        pos = {int(v): i for i, v in enumerate(b_row)}
        assert all(int(v) in pos for v in s_row), \
            "smaller plan probed an item the larger plan skipped"
        idx = [pos[int(v)] for v in s_row]
        assert idx == sorted(idx), \
            "shared candidates changed relative order between plans"


@pytest.mark.slow
@settings(**SETTINGS)
@given(longtail_params())
def test_plans_nest_across_targets(p):
    """Budgets grow elementwise with the target and planned candidate
    sets are prefix-supersets (order-preserving set inclusion)."""
    cidx, queries = build_calibrated(p)
    eng = QueryEngine(cidx, engine="bucket")
    prev_budget, prev_cand = None, None
    for target in TARGETS:
        pl = planner.plan(cidx.calib, target)
        cand = np.asarray(eng.candidates(queries[:8],
                                         budgets=pl.budgets))
        if prev_budget is not None:
            assert all(a <= b for a, b in zip(prev_budget, pl.budgets))
            assert_ordered_subset(prev_cand, cand)
        prev_budget, prev_cand = pl.budgets, cand


@pytest.mark.slow
@settings(**SETTINGS)
@given(longtail_params(), st.sampled_from(["simple", "l2_alsh",
                                           "sign_alsh"]))
def test_engines_agree_on_planned_budgets(p, family):
    """bucket == dense == distributed on the same per-range budgets:
    identical candidate ids and (for distributed) bit-identical merged
    top-k ids."""
    cidx, queries = build_calibrated(p, family=family)
    pl = planner.plan(cidx.calib, 0.8)
    q = queries[:6]
    eng_d = QueryEngine(cidx, engine="dense")
    eng_b = QueryEngine(cidx, engine="bucket", buckets=eng_d.buckets)
    cd = np.asarray(eng_d.candidates(q, budgets=pl.budgets))
    cb = np.asarray(eng_b.candidates(q, budgets=pl.budgets))
    np.testing.assert_array_equal(cd, cb)

    k = min(10, pl.num_probe)
    want_v, want_i = eng_b.query(q, k, budgets=pl.budgets)
    mesh = make_local_mesh()
    shards = mesh.shape["data"]
    sidx = build(cidx.spec, cidx.items, jax.random.PRNGKey(p["seed"] % 97),
                 num_shards=shards)
    placed = distributed.shard_index(sidx, mesh)
    for dist_engine in ("bucket", "dense"):
        deng = distributed.DistributedEngine(placed, mesh,
                                             engine=dist_engine)
        got_v, got_i = deng.query(q, k, budgets=pl.budgets)
        np.testing.assert_array_equal(np.asarray(got_i),
                                      np.asarray(want_i))
        np.testing.assert_allclose(np.asarray(got_v),
                                   np.asarray(want_v),
                                   rtol=2e-6, atol=2e-6)


@pytest.mark.slow
@settings(**SETTINGS)
@given(longtail_params())
def test_recall_meets_planner_contract(p):
    """On the calibration sample the planned recall equals the predicted
    recall (the curves *are* the measurement); held-out queries from the
    same distribution stay within sampling tolerance."""
    cidx, queries = build_calibrated(p, extra_queries=128)
    eng = QueryEngine(cidx, engine="bucket")
    k = cidx.calib.k
    for target in (0.6, 0.9):
        pl = planner.plan(cidx.calib, target)
        assert pl.predicted_recall >= target - 1e-6

        cal_q = queries[:64]
        _, truth = topk.exact_mips(cal_q, cidx.items, k)
        cand = eng.candidates(cal_q, budgets=pl.budgets)
        measured = float(topk.recall_at(cand, truth))
        np.testing.assert_allclose(measured, pl.predicted_recall,
                                   atol=1e-5)

        held = queries[64:]
        _, truth_h = topk.exact_mips(held, cidx.items, k)
        cand_h = eng.candidates(held, budgets=pl.budgets)
        assert float(topk.recall_at(cand_h, truth_h)) \
            >= target - 0.12, "held-out recall fell out of tolerance"


@settings(**SETTINGS)
@given(longtail_params())
def test_adaptive_matches_planned_topk(p):
    """The early-termination arm returns the same top-k as the full
    planned re-rank (the bound is provable, not the eq.-12 estimate) and
    never probes more than the plan."""
    cidx, queries = build_calibrated(p)
    eng = QueryEngine(cidx, engine="bucket")
    pl = planner.plan(cidx.calib, 0.9)
    k = min(5, pl.num_probe)
    q = queries[:8]
    want_v, _ = eng.query(q, k, budgets=pl.budgets)
    got_v, got_i, used = planner.adaptive_query(eng, q, k,
                                               budgets=pl.budgets)
    np.testing.assert_allclose(np.sort(np.asarray(got_v), axis=1),
                               np.sort(np.asarray(want_v), axis=1),
                               rtol=1e-5, atol=1e-6)
    assert (np.asarray(used) <= pl.num_probe).all()
    assert (np.asarray(used) >= min(k, pl.num_probe)).all()
