"""Serving path: jitted decode, LSH-decode head, batched generation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.launch import serve
from repro.launch.mesh import make_local_mesh
from repro.models import lm, lm_head


@pytest.fixture(scope="module")
def small_lm():
    cfg = get_config("qwen3_0_6b").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_decode_step_jitted(small_lm):
    cfg, params = small_lm
    mesh = make_local_mesh()
    fn = serve.make_decode_step(cfg, mesh)
    caches = lm.init_cache(cfg, 4, 32)
    logits, caches = fn(params, jnp.zeros((4,), jnp.int32), caches,
                        jnp.asarray(0, jnp.int32))
    assert logits.shape == (4, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits[:, :cfg.vocab]).all())


def test_lsh_decode_head_agreement(small_lm):
    """LSH-decode top-1 matches exact greedy for most positions at a
    moderate probe budget, and exactly at full probe budget."""
    cfg, params = small_lm
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    hidden = jax.random.normal(jax.random.PRNGKey(1), (16, cfg.d_model))
    index = lm_head.build_vocab_index(unembed, jax.random.PRNGKey(2),
                                      code_len=64, num_ranges=16)
    _, exact = lm_head.exact_topk_tokens(hidden, unembed, 1,
                                         true_vocab=cfg.vocab)
    _, full = lm_head.lsh_topk_tokens(index, hidden, unembed, k=1,
                                      num_probe=cfg.padded_vocab,
                                      true_vocab=cfg.vocab)
    np.testing.assert_array_equal(np.asarray(full), np.asarray(exact))
    _, approx = lm_head.lsh_topk_tokens(index, hidden, unembed, k=1,
                                        num_probe=128,
                                        true_vocab=cfg.vocab)
    agree = float(jnp.mean((approx[:, 0] == exact[:, 0])
                           .astype(jnp.float32)))
    assert agree >= 0.5


def test_batched_server_generate(small_lm):
    cfg, params = small_lm
    mesh = make_local_mesh()
    server = serve.BatchedServer(cfg, params, mesh, max_seq=32)
    prompts = jax.random.randint(jax.random.PRNGKey(4), (2, 5), 0,
                                 cfg.vocab)
    out = server.generate(prompts, steps=4)
    assert out.shape == (2, 4)
    assert bool((out >= 0).all()) and bool((out < cfg.vocab).all())


def test_batched_server_lsh_decode(small_lm):
    cfg, params = small_lm
    mesh = make_local_mesh()
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    vidx = lm_head.build_vocab_index(unembed, jax.random.PRNGKey(5),
                                     code_len=64, num_ranges=16)
    server = serve.BatchedServer(cfg, params, mesh, max_seq=32,
                                 lsh_decode=True, vocab_index=vidx,
                                 num_probe=cfg.padded_vocab)
    exact_server = serve.BatchedServer(cfg, params, mesh, max_seq=32)
    prompts = jax.random.randint(jax.random.PRNGKey(6), (2, 5), 0,
                                 cfg.vocab)
    out_lsh = server.generate(prompts, steps=3)
    out_exact = exact_server.generate(prompts, steps=3)
    # full probe budget => greedy decode is identical
    np.testing.assert_array_equal(np.asarray(out_lsh),
                                  np.asarray(out_exact))


def test_batched_server_bucket_engine(small_lm):
    """engine="bucket" decode: full probe budget => identical greedy output
    to the exact server (candidates cover the whole vocab)."""
    cfg, params = small_lm
    mesh = make_local_mesh()
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    vidx = lm_head.build_vocab_index(unembed, jax.random.PRNGKey(5),
                                     code_len=64, num_ranges=16)
    server = serve.BatchedServer(cfg, params, mesh, max_seq=32,
                                 lsh_decode=True, vocab_index=vidx,
                                 num_probe=cfg.padded_vocab,
                                 engine="bucket")
    exact_server = serve.BatchedServer(cfg, params, mesh, max_seq=32)
    prompts = jax.random.randint(jax.random.PRNGKey(6), (2, 5), 0,
                                 cfg.vocab)
    out_bucket = server.generate(prompts, steps=3)
    out_exact = exact_server.generate(prompts, steps=3)
    np.testing.assert_array_equal(np.asarray(out_bucket),
                                  np.asarray(out_exact))


def test_batched_server_fused_engine(small_lm):
    """engine="fused" decode (DESIGN.md §17): the single-pass head at full
    probe budget produces identical greedy output to the exact server —
    the jitted step returns the hidden state and the fused kernel scores
    the traversal host-dispatched, like the streaming/sharded heads."""
    cfg, params = small_lm
    mesh = make_local_mesh()
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    vidx = lm_head.build_vocab_index(unembed, jax.random.PRNGKey(5),
                                     code_len=64, num_ranges=16)
    server = serve.BatchedServer(cfg, params, mesh, max_seq=32,
                                 lsh_decode=True, vocab_index=vidx,
                                 num_probe=cfg.padded_vocab,
                                 engine="fused")
    exact_server = serve.BatchedServer(cfg, params, mesh, max_seq=32)
    prompts = jax.random.randint(jax.random.PRNGKey(6), (2, 5), 0,
                                 cfg.vocab)
    out_fused = server.generate(prompts, steps=3)
    out_exact = exact_server.generate(prompts, steps=3)
    np.testing.assert_array_equal(np.asarray(out_fused),
                                  np.asarray(out_exact))
    # quantized arm serves without error (greedy parity is tolerance-
    # bounded, not exact — covered by the recall-delta conformance test)
    q_server = serve.BatchedServer(cfg, params, mesh, max_seq=32,
                                   lsh_decode=True, vocab_index=vidx,
                                   num_probe=cfg.padded_vocab,
                                   engine="fused", quantized=True)
    out_q = q_server.generate(prompts, steps=3)
    assert out_q.shape == out_exact.shape
    with pytest.raises(ValueError, match="fused"):
        serve.BatchedServer(cfg, params, mesh, lsh_decode=True,
                            vocab_index=vidx, engine="bucket",
                            quantized=True)


def test_bucket_arrays_roundtrip(small_lm):
    """The replicated-array plumbing the decode step (and the streaming
    path) relies on: a bucket store shipped as plain arrays and rebuilt on
    the other side emits exactly the candidates of a QueryEngine driven by
    the original store."""
    from repro.core.bucket_index import BucketIndex, build_bucket_index
    from repro.core.engine import QueryEngine, bucket_candidates, \
        encode_queries

    cfg, params = small_lm
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    vidx = lm_head.build_vocab_index(unembed, jax.random.PRNGKey(5),
                                     code_len=64, num_ranges=16)
    buckets = build_bucket_index(vidx)
    arrs = serve.bucket_arrays(buckets)         # what rides to the step
    rebuilt = BucketIndex(arrs["item_ids"], arrs["bucket_start"],
                          arrs["bucket_rid"], arrs["bucket_code"],
                          arrs["rank"], vidx.hash_bits, vidx.eps)
    hidden = jax.random.normal(jax.random.PRNGKey(6), (8, cfg.d_model))
    q_codes = encode_queries(vidx, hidden)
    got = bucket_candidates(rebuilt, q_codes, 256)
    eng = QueryEngine(vidx, engine="bucket", buckets=buckets)
    want = eng.candidates(hidden, 256)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_batched_server_sharded_index(small_lm):
    """Distributed-head server (DESIGN.md §11): full probe budget matches
    the exact server greedy output; the decode step returns hidden states
    and the sharded engine runs the merge collective."""
    cfg, params = small_lm
    mesh = make_local_mesh(model_parallel=1)
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    sidx = serve.build_sharded_vocab_index(
        unembed, jax.random.PRNGKey(5), code_len=32, num_ranges=8,
        num_shards=mesh.shape["model"], true_vocab=cfg.vocab)
    server = serve.BatchedServer(cfg, params, mesh, max_seq=32,
                                 sharded_index=sidx,
                                 num_probe=cfg.padded_vocab)
    exact_server = serve.BatchedServer(cfg, params, mesh, max_seq=32)
    prompts = jax.random.randint(jax.random.PRNGKey(6), (2, 5), 0,
                                 cfg.vocab)
    out_sharded = server.generate(prompts, steps=3)
    out_exact = exact_server.generate(prompts, steps=3)
    np.testing.assert_array_equal(np.asarray(out_sharded),
                                  np.asarray(out_exact))
    # sharded head ids ARE token ids: a token_map is a category error
    with pytest.raises(ValueError, match="token_map"):
        serve.BatchedServer(cfg, params, mesh, max_seq=32,
                            sharded_index=sidx,
                            token_map=np.zeros((4,), np.int32))


def test_batched_server_streaming_head(small_lm):
    """Mutable-head server: full probe budget matches the exact server;
    delete_tokens bans a token from decoding; insert_tokens with a boosted
    alias row wins it back."""
    cfg, params = small_lm
    mesh = make_local_mesh()
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    sidx = serve.build_streaming_vocab_index(
        unembed, jax.random.PRNGKey(5), code_len=32, num_ranges=8,
        capacity=32)
    server = serve.BatchedServer(cfg, params, mesh, max_seq=32,
                                 streaming_index=sidx,
                                 num_probe=cfg.padded_vocab)
    exact_server = serve.BatchedServer(cfg, params, mesh, max_seq=32)
    prompts = jax.random.randint(jax.random.PRNGKey(6), (2, 5), 0,
                                 cfg.vocab)
    out_stream = server.generate(prompts, steps=3)
    out_exact = exact_server.generate(prompts, steps=3)
    np.testing.assert_array_equal(np.asarray(out_stream),
                                  np.asarray(out_exact))
    # ban the greedy first token of request 0: it must not come back
    banned = int(out_exact[0, 0])
    server.delete_tokens([banned])
    out_banned = server.generate(prompts, steps=1)
    assert int(out_banned[0, 0]) != banned
    # upsert: a 2x-boosted alias column decoding back to the banned token
    col = (params["embed"].T if cfg.tie_embeddings
           else params["unembed"])[:, banned]
    ids = server.insert_tokens(2.0 * col[None, :], [banned])
    assert int(ids[0]) >= cfg.padded_vocab
    out_boost = server.generate(prompts, steps=1)
    assert int(out_boost[0, 0]) == banned


def test_batched_server_mounts_index_with_pending_delta(small_lm):
    """A server mounting an index that already carries un-compacted delta
    traffic (the load_index flow) must map every assigned id, and
    insert_tokens must stay contiguous with the token map."""
    cfg, params = small_lm
    mesh = make_local_mesh()
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    sidx = serve.build_streaming_vocab_index(
        unembed, jax.random.PRNGKey(5), code_len=32, num_ranges=8,
        capacity=32)
    pre = sidx.insert(1e-3 * jnp.ones((2, cfg.d_model)))   # before mounting
    # identity can't cover non-vocab rows: an explicit map is required
    with pytest.raises(ValueError):
        serve.BatchedServer(cfg, params, mesh, max_seq=32,
                            streaming_index=sidx,
                            num_probe=cfg.padded_vocab)
    tmap = np.concatenate([np.arange(sidx.store_size, dtype=np.int32),
                           np.zeros((2,), np.int32)])
    server = serve.BatchedServer(cfg, params, mesh, max_seq=32,
                                 streaming_index=sidx,
                                 num_probe=cfg.padded_vocab,
                                 token_map=tmap)
    assert server._token_map.shape[0] == sidx.store_size + 2
    prompts = jax.random.randint(jax.random.PRNGKey(6), (2, 5), 0,
                                 cfg.vocab)
    out = server.generate(prompts, steps=2)
    assert out.shape == (2, 2)
    assert bool((out < cfg.vocab).all())    # every id decodes embeddable
    ids = server.insert_tokens(jnp.ones((1, cfg.d_model)), [0])
    assert int(ids[0]) == int(pre[-1]) + 1
    live_before = server.streaming_index.live_count
    map_before = server._token_map.shape[0]
    with pytest.raises(ValueError):     # mismatch rejected before mutation
        server.insert_tokens(jnp.ones((2, cfg.d_model)), [0])
    assert server.streaming_index.live_count == live_before
    assert server._token_map.shape[0] == map_before


def test_greedy_continuation_matches_teacher_forcing(small_lm):
    """prefill -> extend_cache -> decode produces the same next token as a
    full forward pass at each step (teacher-forced prefix)."""
    cfg, params = small_lm
    B, S = 2, 6
    toks = jax.random.randint(jax.random.PRNGKey(7), (B, S), 0, cfg.vocab)
    last_hidden, caches = lm.prefill(params, toks, cfg)
    caches = lm.extend_cache(cfg, caches, 16)
    # teacher forcing: full forward over the same prefix
    h_full, _, _ = lm.backbone_forward(
        params, lm._embed(params, toks, cfg), jnp.arange(S), cfg)
    h_full = lm.rms_norm(h_full, params["final_norm"], cfg.norm_eps)
    np.testing.assert_allclose(np.asarray(last_hidden, np.float32),
                               np.asarray(h_full[:, -1], np.float32),
                               atol=3e-2, rtol=3e-2)
    # one decode step from the prefill cache == forward at position S
    nxt = jax.random.randint(jax.random.PRNGKey(8), (B,), 0, cfg.vocab)
    h_dec, _ = lm.decode_step(params, nxt, caches,
                              jnp.asarray(S, jnp.int32), cfg,
                              logits_mode="none")
    toks2 = jnp.concatenate([toks, nxt[:, None]], axis=1)
    h_full2, _, _ = lm.backbone_forward(
        params, lm._embed(params, toks2, cfg), jnp.arange(S + 1), cfg)
    h_full2 = lm.rms_norm(h_full2, params["final_norm"], cfg.norm_eps)
    np.testing.assert_allclose(np.asarray(h_dec, np.float32),
                               np.asarray(h_full2[:, -1], np.float32),
                               atol=3e-2, rtol=3e-2)
