"""Serving path: jitted decode, LSH-decode head, batched generation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.launch import serve
from repro.launch.mesh import make_local_mesh
from repro.models import lm, lm_head


@pytest.fixture(scope="module")
def small_lm():
    cfg = get_config("qwen3_0_6b").reduced()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_decode_step_jitted(small_lm):
    cfg, params = small_lm
    mesh = make_local_mesh()
    fn = serve.make_decode_step(cfg, mesh)
    caches = lm.init_cache(cfg, 4, 32)
    logits, caches = fn(params, jnp.zeros((4,), jnp.int32), caches,
                        jnp.asarray(0, jnp.int32))
    assert logits.shape == (4, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits[:, :cfg.vocab]).all())


def test_lsh_decode_head_agreement(small_lm):
    """LSH-decode top-1 matches exact greedy for most positions at a
    moderate probe budget, and exactly at full probe budget."""
    cfg, params = small_lm
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    hidden = jax.random.normal(jax.random.PRNGKey(1), (16, cfg.d_model))
    index = lm_head.build_vocab_index(unembed, jax.random.PRNGKey(2),
                                      code_len=64, num_ranges=16)
    _, exact = lm_head.exact_topk_tokens(hidden, unembed, 1,
                                         true_vocab=cfg.vocab)
    _, full = lm_head.lsh_topk_tokens(index, hidden, unembed, k=1,
                                      num_probe=cfg.padded_vocab,
                                      true_vocab=cfg.vocab)
    np.testing.assert_array_equal(np.asarray(full), np.asarray(exact))
    _, approx = lm_head.lsh_topk_tokens(index, hidden, unembed, k=1,
                                        num_probe=128,
                                        true_vocab=cfg.vocab)
    agree = float(jnp.mean((approx[:, 0] == exact[:, 0])
                           .astype(jnp.float32)))
    assert agree >= 0.5


def test_batched_server_generate(small_lm):
    cfg, params = small_lm
    mesh = make_local_mesh()
    server = serve.BatchedServer(cfg, params, mesh, max_seq=32)
    prompts = jax.random.randint(jax.random.PRNGKey(4), (2, 5), 0,
                                 cfg.vocab)
    out = server.generate(prompts, steps=4)
    assert out.shape == (2, 4)
    assert bool((out >= 0).all()) and bool((out < cfg.vocab).all())


def test_batched_server_lsh_decode(small_lm):
    cfg, params = small_lm
    mesh = make_local_mesh()
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    vidx = lm_head.build_vocab_index(unembed, jax.random.PRNGKey(5),
                                     code_len=64, num_ranges=16)
    server = serve.BatchedServer(cfg, params, mesh, max_seq=32,
                                 lsh_decode=True, vocab_index=vidx,
                                 num_probe=cfg.padded_vocab)
    exact_server = serve.BatchedServer(cfg, params, mesh, max_seq=32)
    prompts = jax.random.randint(jax.random.PRNGKey(6), (2, 5), 0,
                                 cfg.vocab)
    out_lsh = server.generate(prompts, steps=3)
    out_exact = exact_server.generate(prompts, steps=3)
    # full probe budget => greedy decode is identical
    np.testing.assert_array_equal(np.asarray(out_lsh),
                                  np.asarray(out_exact))


def test_batched_server_bucket_engine(small_lm):
    """engine="bucket" decode: full probe budget => identical greedy output
    to the exact server (candidates cover the whole vocab)."""
    cfg, params = small_lm
    mesh = make_local_mesh()
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    vidx = lm_head.build_vocab_index(unembed, jax.random.PRNGKey(5),
                                     code_len=64, num_ranges=16)
    server = serve.BatchedServer(cfg, params, mesh, max_seq=32,
                                 lsh_decode=True, vocab_index=vidx,
                                 num_probe=cfg.padded_vocab,
                                 engine="bucket")
    exact_server = serve.BatchedServer(cfg, params, mesh, max_seq=32)
    prompts = jax.random.randint(jax.random.PRNGKey(6), (2, 5), 0,
                                 cfg.vocab)
    out_bucket = server.generate(prompts, steps=3)
    out_exact = exact_server.generate(prompts, steps=3)
    np.testing.assert_array_equal(np.asarray(out_bucket),
                                  np.asarray(out_exact))


def test_greedy_continuation_matches_teacher_forcing(small_lm):
    """prefill -> extend_cache -> decode produces the same next token as a
    full forward pass at each step (teacher-forced prefix)."""
    cfg, params = small_lm
    B, S = 2, 6
    toks = jax.random.randint(jax.random.PRNGKey(7), (B, S), 0, cfg.vocab)
    last_hidden, caches = lm.prefill(params, toks, cfg)
    caches = lm.extend_cache(cfg, caches, 16)
    # teacher forcing: full forward over the same prefix
    h_full, _, _ = lm.backbone_forward(
        params, lm._embed(params, toks, cfg), jnp.arange(S), cfg)
    h_full = lm.rms_norm(h_full, params["final_norm"], cfg.norm_eps)
    np.testing.assert_allclose(np.asarray(last_hidden, np.float32),
                               np.asarray(h_full[:, -1], np.float32),
                               atol=3e-2, rtol=3e-2)
    # one decode step from the prefill cache == forward at position S
    nxt = jax.random.randint(jax.random.PRNGKey(8), (B,), 0, cfg.vocab)
    h_dec, _ = lm.decode_step(params, nxt, caches,
                              jnp.asarray(S, jnp.int32), cfg,
                              logits_mode="none")
    toks2 = jnp.concatenate([toks, nxt[:, None]], axis=1)
    h_full2, _, _ = lm.backbone_forward(
        params, lm._embed(params, toks2, cfg), jnp.arange(S + 1), cfg)
    h_full2 = lm.rms_norm(h_full2, params["final_norm"], cfg.norm_eps)
    np.testing.assert_allclose(np.asarray(h_dec, np.float32),
                               np.asarray(h_full2[:, -1], np.float32),
                               atol=3e-2, rtol=3e-2)
