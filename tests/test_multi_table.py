"""Multi-table single-probe LSH (supplementary comparison mode)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import multi_table, topk


def test_candidates_are_exact_bucket_matches(longtail_ds):
    idx = multi_table.build(longtail_ds.items, jax.random.PRNGKey(0),
                            code_len=8, num_tables=4, num_ranges=8)
    q = longtail_ds.queries[:4]
    scores = multi_table.candidate_scores(idx, q)
    # scores are (match count) * U_j; count <= num_tables
    counts = np.asarray(scores) / np.asarray(
        idx.upper[idx.range_id])[None, :]
    assert counts.max() <= 4 + 1e-5
    assert counts.min() >= 0


def test_query_returns_only_candidates(longtail_ds):
    idx = multi_table.build(longtail_ds.items, jax.random.PRNGKey(0),
                            code_len=16, num_tables=2, num_ranges=8)
    q = longtail_ds.queries[:8]
    vals, ids, n_cand = multi_table.query(idx, q, 10)
    v, i = np.asarray(vals), np.asarray(ids)
    # every finite val corresponds to a real item and matches its IP
    items = np.asarray(longtail_ds.items)
    qs = np.asarray(q)
    for r in range(8):
        for c in range(10):
            if np.isfinite(v[r, c]):
                assert i[r, c] >= 0
                np.testing.assert_allclose(
                    v[r, c], qs[r] @ items[i[r, c]], rtol=1e-4)
            else:
                assert i[r, c] == -1


def test_more_tables_more_recall(longtail_ds):
    q = longtail_ds.queries
    _, truth = topk.exact_mips(q, longtail_ds.items, 10)
    recs = []
    for T in (2, 16):
        idx = multi_table.build(longtail_ds.items, jax.random.PRNGKey(1),
                                code_len=8, num_tables=T, num_ranges=8)
        _, ids, _ = multi_table.query(idx, q, 10)
        recs.append(float(topk.recall_at(
            jnp.where(ids >= 0, ids, longtail_ds.items.shape[0] + 1),
            truth)))
    assert recs[1] > recs[0]
